"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:        # only the property-based sweep needs hypothesis
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.kernels import ops, ref
from repro.kernels.int8_matmul import quantize_int8

K = jax.random.PRNGKey


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kh,d", [
    (1, 128, 4, 4, 64),       # MHA, exact block multiple
    (2, 200, 4, 2, 64),       # GQA, padded seq
    (1, 384, 8, 1, 128),      # MQA, d=128
    (1, 96, 2, 2, 32),        # seq < block
])
def test_flash_attention_matches_ref(b, s, h, kh, d, dtype):
    ks = jax.random.split(K(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kh, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kh, d), dtype)
    out = ops.flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention_ref(jnp.swapaxes(q, 1, 2),
                                   jnp.swapaxes(k, 1, 2),
                                   jnp.swapaxes(v, 1, 2))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(jnp.swapaxes(want, 1, 2), np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("window", [16, 64, 128])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_flash_attention_window_softcap(window, softcap):
    b, s, h, kh, d = 1, 256, 4, 2, 64
    ks = jax.random.split(K(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    out = ops.flash_attention(q, k, v, window=window, softcap=softcap,
                              interpret=True)
    want = ref.flash_attention_ref(jnp.swapaxes(q, 1, 2),
                                   jnp.swapaxes(k, 1, 2),
                                   jnp.swapaxes(v, 1, 2),
                                   window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.swapaxes(want, 1, 2)),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_block_shape_independence():
    """Result must not depend on the BlockSpec tiling."""
    b, s, h, kh, d = 1, 512, 2, 2, 64
    ks = jax.random.split(K(2), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    a = ops.flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    bq = ops.flash_attention(q, k, v, block_q=256, block_k=128, interpret=True)
    c = ops.flash_attention(q, k, v, block_q=128, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bq), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------- #
# decode attention
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kh,d,c,valid", [
    (2, 4, 2, 64, 512, 512),
    (1, 8, 1, 128, 700, 650),     # padded cache, partially filled
    (4, 2, 2, 32, 64, 10),
])
def test_decode_attention_matches_ref(b, h, kh, d, c, valid, dtype):
    ks = jax.random.split(K(3), 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, c, kh, d), dtype)
    vc = jax.random.normal(ks[2], (b, c, kh, d), dtype)
    key_pos = jnp.where(jnp.arange(c) < valid, jnp.arange(c), -1).astype(jnp.int32)
    pos = jnp.asarray(valid - 1, jnp.int32)
    out = ops.decode_attention(q, kc, vc, key_pos, pos, block_c=256,
                               interpret=True)
    mask = (key_pos >= 0) & (key_pos <= pos)
    want = ref.decode_attention_ref(q, kc, vc, mask[None])
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_ring_buffer_window():
    """Ring-buffer semantics: slots hold non-monotonic positions."""
    b, h, kh, d, c = 1, 2, 1, 32, 128
    ks = jax.random.split(K(4), 3)
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, c, kh, d))
    vc = jax.random.normal(ks[2], (b, c, kh, d))
    pos = jnp.asarray(200, jnp.int32)           # wrapped: slot = pos % 128
    key_pos = ((jnp.arange(c) + (201 // c) * c)
               - jnp.where(jnp.arange(c) > 200 % c, c, 0)).astype(jnp.int32)
    window = 50
    out = ops.decode_attention(q, kc, vc, key_pos, pos, window=window,
                               interpret=True)
    mask = (key_pos >= 0) & (key_pos <= pos) & (key_pos > pos - window)
    want = ref.decode_attention_ref(q, kc, vc, mask[None])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------------------- #
# paged decode attention (block-table indirection fused into the kernel)
# --------------------------------------------------------------------------- #

def _paged_case(b, kh, d, bs, nbs, num_blocks, lens, seed):
    """Pools + a block table with the last entry of row 0 unmapped (-1)."""
    c = nbs * bs
    ks = jax.random.split(K(seed), 3)
    k_pool = jax.random.normal(ks[0], (num_blocks + 1, bs, kh, d))
    v_pool = jax.random.normal(ks[1], (num_blocks + 1, bs, kh, d))
    rng = np.random.default_rng(seed)
    bt = rng.permutation(num_blocks)[:b * nbs].reshape(b, nbs).astype(np.int32)
    bt[0, -1] = -1                      # unmapped tail: must read as masked
    lens = np.asarray(lens)
    key_pos = np.where(np.arange(c)[None] < lens[:, None],
                       np.arange(c)[None], -1).astype(np.int32)
    key_pos[0, (nbs - 1) * bs:] = -1    # nothing valid in the unmapped block
    pos = (lens - 1).astype(np.int32)
    return (k_pool, v_pool, jnp.asarray(bt), jnp.asarray(key_pos),
            jnp.asarray(pos), ks[2])


def _paged_gather_ref(q, k_pool, v_pool, bt, mask, *, softcap=None):
    """Oracle: dense gather through the table, then masked sdpa per row."""
    b, nbs = bt.shape
    bs, kh, d = k_pool.shape[1:]
    read = jnp.clip(bt, 0, None)
    ck = k_pool[read].reshape(b, nbs * bs, kh, d)
    cv = v_pool[read].reshape(b, nbs * bs, kh, d)
    return jnp.concatenate(
        [ref.decode_attention_ref(q[i:i + 1], ck[i:i + 1], cv[i:i + 1],
                                  mask[i:i + 1], softcap=softcap)
         for i in range(b)], axis=0)


@pytest.mark.parametrize("softcap", [None, 30.0])
@pytest.mark.parametrize("b,h,kh,d,bs,nbs,lens", [
    (2, 4, 2, 64, 16, 4, (40, 25)),      # GQA, per-slot positions
    (3, 8, 1, 32, 16, 3, (48, 1, 17)),   # MQA, a fresh slot and a full one
    (1, 2, 2, 128, 32, 2, (33, )),       # MHA, bigger blocks
])
def test_paged_decode_matches_gather_ref(b, h, kh, d, bs, nbs, lens, softcap):
    """Kernel reads through the block table == dense gather + masked sdpa,
    with every row at its own position (per-slot semantics)."""
    k_pool, v_pool, bt, key_pos, pos, kq = _paged_case(
        b, kh, d, bs, nbs, num_blocks=b * nbs + 2, lens=lens, seed=20)
    q = jax.random.normal(kq, (b, h, d))
    out = ops.paged_decode_attention(q, k_pool, v_pool, bt, key_pos, pos,
                                     softcap=softcap, interpret=True)
    mask = (key_pos >= 0) & (key_pos <= pos[:, None])
    want = _paged_gather_ref(q, k_pool, v_pool, bt, mask, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_paged_decode_ring_wraparound_window():
    """Positions past C_pad wrap the ring: slots hold non-monotonic
    key_pos, and the window mask must follow positions, not slot order."""
    b, h, kh, d, bs, nbs = 1, 2, 1, 32, 16, 4
    c = nbs * bs                                  # 64
    ks = jax.random.split(K(21), 3)
    k_pool = jax.random.normal(ks[0], (nbs + 1, bs, kh, d))
    v_pool = jax.random.normal(ks[1], (nbs + 1, bs, kh, d))
    q = jax.random.normal(ks[2], (b, h, d))
    bt = jnp.arange(nbs, dtype=jnp.int32)[None]
    pos = jnp.asarray([150], jnp.int32)           # wrapped: slot = pos % 64
    wrap = 150 % c
    key_pos = (jnp.arange(c) + (150 // c) * c
               - jnp.where(jnp.arange(c) > wrap, c, 0)).astype(jnp.int32)[None]
    window = 40
    out = ops.paged_decode_attention(q, k_pool, v_pool, bt, key_pos, pos,
                                     window=window, interpret=True)
    mask = (key_pos >= 0) & (key_pos <= pos[:, None]) \
        & (key_pos > pos[:, None] - window)
    assert 0 < int(mask.sum()) < c, "window must mask a strict subset"
    want = _paged_gather_ref(q, k_pool, v_pool, bt, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_paged_decode_fully_masked_row_is_finite():
    """An idle slot (every key_pos == -1, table unmapped) must produce
    finite output (exact zeros), not NaN from an empty softmax."""
    b, h, kh, d, bs, nbs = 2, 4, 2, 32, 16, 2
    k_pool, v_pool, bt, key_pos, pos, kq = _paged_case(
        b, kh, d, bs, nbs, num_blocks=b * nbs, lens=(20, 5), seed=22)
    q = jax.random.normal(kq, (b, h, d))
    key_pos = key_pos.at[1].set(-1)               # row 1: never written
    bt = bt.at[1].set(-1)
    out = ops.paged_decode_attention(q, k_pool, v_pool, bt, key_pos, pos,
                                     interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_array_equal(np.asarray(out[1]),
                                  np.zeros_like(np.asarray(out[1])))
    # the live row is unaffected by its dead neighbour
    solo = ops.paged_decode_attention(q[:1], k_pool, v_pool, bt[:1],
                                      key_pos[:1], pos[:1], interpret=True)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(solo[0]),
                               rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------------------- #
# paged verify attention (KQ draft tokens per slot, one block-streaming pass)
# --------------------------------------------------------------------------- #

def _paged_verify_gather_ref(q, k_pool, v_pool, bt, mask, *, softcap=None):
    """Oracle: per q row, the single-token gather reference with that
    row's causality mask."""
    kq = q.shape[1]
    return jnp.stack(
        [_paged_gather_ref(q[:, i], k_pool, v_pool, bt, mask[:, i],
                           softcap=softcap) for i in range(kq)], axis=1)


def _verify_case(b, kh, d, bs, nbs, kq, lens, seed, unmapped_tail=False):
    """Pools + table where each slot holds ``lens[i] + kq - 1`` scattered
    keys (the history plus the verify quantum's own drafts) and ``pos`` is
    the first fed token's position, matching the runtime's scatter-then-
    attend order."""
    c = nbs * bs
    assert max(lens) + kq - 1 <= c
    ks = jax.random.split(K(seed), 3)
    num_blocks = b * nbs + 2
    k_pool = jax.random.normal(ks[0], (num_blocks + 1, bs, kh, d))
    v_pool = jax.random.normal(ks[1], (num_blocks + 1, bs, kh, d))
    rng = np.random.default_rng(seed)
    bt = rng.permutation(num_blocks)[:b * nbs].reshape(b, nbs).astype(np.int32)
    valid = np.asarray(lens)[:, None] + kq - 1
    key_pos = np.where(np.arange(c)[None] < valid,
                       np.arange(c)[None], -1).astype(np.int32)
    if unmapped_tail:
        bt[0, -1] = -1
        key_pos[0, (nbs - 1) * bs:] = -1
    pos = (np.asarray(lens) - 1).astype(np.int32)
    return (k_pool, v_pool, jnp.asarray(bt), jnp.asarray(key_pos),
            jnp.asarray(pos), ks[2])


@pytest.mark.parametrize("softcap", [None, 30.0])
@pytest.mark.parametrize("b,h,kh,d,bs,nbs,kq,lens", [
    (2, 4, 2, 64, 16, 4, 4, (40, 25)),    # GQA, per-slot positions
    (2, 8, 1, 32, 16, 3, 4, (15, 30)),    # MQA; row 0's drafts straddle the
                                          # block-0/1 boundary (15-1+4 > 16)
    (1, 2, 2, 64, 16, 2, 5, (20, )),      # kq > typical draft count
])
def test_paged_verify_matches_gather_ref(b, h, kh, d, bs, nbs, kq, lens,
                                         softcap):
    """KQ-row verify == per-row gather reference under per-row causality:
    row i admits keys with key_pos <= pos + i (later drafts see earlier
    drafts' freshly-scattered keys, never their own future)."""
    k_pool, v_pool, bt, key_pos, pos, kr = _verify_case(
        b, kh, d, bs, nbs, kq, lens, seed=30)
    q = jax.random.normal(kr, (b, kq, h, d))
    out = ops.paged_verify_attention(q, k_pool, v_pool, bt, key_pos, pos,
                                     softcap=softcap, interpret=True)
    pos_i = pos[:, None, None] + jnp.arange(kq)[None, :, None]
    mask = (key_pos[:, None, :] >= 0) & (key_pos[:, None, :] <= pos_i)
    want = _paged_verify_gather_ref(q, k_pool, v_pool, bt, mask,
                                    softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    # per-row causality is strict: row 0 must NOT see row kq-1's keys
    m0, mk = mask[:, 0], mask[:, kq - 1]
    assert int(m0.sum()) < int(mk.sum())


def test_paged_verify_unmapped_blocks_masked():
    """An unmapped (-1) table entry reads as fully masked — the scratch
    block's garbage never reaches a verify row's softmax."""
    b, h, kh, d, bs, nbs, kq = 2, 4, 2, 32, 16, 3, 3
    k_pool, v_pool, bt, key_pos, pos, kr = _verify_case(
        b, kh, d, bs, nbs, kq, lens=(20, 10), seed=31, unmapped_tail=True)
    q = jax.random.normal(kr, (b, kq, h, d))
    out = ops.paged_verify_attention(q, k_pool, v_pool, bt, key_pos, pos,
                                     interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    pos_i = pos[:, None, None] + jnp.arange(kq)[None, :, None]
    mask = (key_pos[:, None, :] >= 0) & (key_pos[:, None, :] <= pos_i)
    want = _paged_verify_gather_ref(q, k_pool, v_pool, bt, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    # corrupting the scratch block (last pool row) must not change outputs
    out2 = ops.paged_verify_attention(
        q, k_pool.at[-1].set(1e6), v_pool.at[-1].set(-1e6), bt, key_pos, pos,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_paged_verify_kq1_bitexact_with_decode():
    """A 1-token verify IS the decode kernel: identical online-softmax
    order makes the outputs bit-identical, which is what lets the runtime
    route plain decode through the verify path without drift."""
    b, h, kh, d, bs, nbs = 3, 4, 2, 64, 16, 4
    k_pool, v_pool, bt, key_pos, pos, kr = _paged_case(
        b, kh, d, bs, nbs, num_blocks=b * nbs + 2, lens=(40, 25, 7), seed=32)
    q = jax.random.normal(kr, (b, h, d))
    dec = ops.paged_decode_attention(q, k_pool, v_pool, bt, key_pos, pos,
                                     interpret=True)
    ver = ops.paged_verify_attention(q[:, None], k_pool, v_pool, bt,
                                     key_pos, pos, interpret=True)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(ver[:, 0]))


def test_paged_verify_ring_wraparound_window():
    """Wrapped ring + sliding window: each verify row's window follows its
    own position pos+i over non-monotonic key_pos."""
    b, h, kh, d, bs, nbs, kq = 1, 2, 1, 32, 16, 4, 3
    c = nbs * bs                                  # 64
    ks = jax.random.split(K(33), 3)
    k_pool = jax.random.normal(ks[0], (nbs + 1, bs, kh, d))
    v_pool = jax.random.normal(ks[1], (nbs + 1, bs, kh, d))
    q = jax.random.normal(ks[2], (b, kq, h, d))
    bt = jnp.arange(nbs, dtype=jnp.int32)[None]
    first = 150                                   # wrapped: slot = pos % 64
    wrap = (first + kq - 1) % c
    key_pos = (jnp.arange(c) + ((first + kq - 1) // c) * c
               - jnp.where(jnp.arange(c) > wrap, c, 0)).astype(jnp.int32)[None]
    pos = jnp.asarray([first], jnp.int32)
    window = 40
    out = ops.paged_verify_attention(q, k_pool, v_pool, bt, key_pos, pos,
                                     window=window, interpret=True)
    pos_i = pos[:, None, None] + jnp.arange(kq)[None, :, None]
    mask = (key_pos[:, None, :] >= 0) & (key_pos[:, None, :] <= pos_i) \
        & (key_pos[:, None, :] > pos_i - window)
    counts = [int(mask[0, i].sum()) for i in range(kq)]
    assert all(0 < n < c for n in counts), counts
    want = _paged_verify_gather_ref(q, k_pool, v_pool, bt, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---- model-level: attend_decode_paged dispatch (per-slot vs shared,
# ---- write_mask scratch isolation, impl contract)

def _attn_fixture():
    from repro.configs import get_config
    from repro.models import attention as A
    from repro.models.kvcache import init_paged_block_cache
    from repro.models.layers import ParamBuilder
    cfg = get_config("qwen3-0.6b").reduced(n_layers=2)
    spec = [s for s in cfg.layer_specs() if s.kind == "attn"][0]
    pb = ParamBuilder(K(23), jnp.float32)
    A.init_attention(pb, "mixer", cfg)

    def make_cache(batch, num_blocks=8, max_len=32):
        cache = init_paged_block_cache(cfg, spec, batch, max_len, num_blocks,
                                       16, jnp.float32)
        cache["k_pool"] = jax.random.normal(K(24), cache["k_pool"].shape)
        cache["v_pool"] = jax.random.normal(K(25), cache["v_pool"].shape)
        return cache

    return cfg, spec, pb.params["mixer"], make_cache


def test_attend_decode_paged_per_slot_matches_shared():
    """Shared semantics (scalar pos, the pipeline tick's view) must equal
    the same slot decoded through the per-slot convention."""
    from repro.models import attention as A
    cfg, spec, params, make_cache = _attn_fixture()
    x = jax.random.normal(K(26), (1, 1, cfg.d_model))
    per = make_cache(1)
    per["bt"] = jnp.array([[0, 1]], jnp.int32)
    per["key_pos"] = per["key_pos"].at[0, :20].set(jnp.arange(20))
    per["pos"] = jnp.array([20], jnp.int32)
    shared = dict(per, bt=per["bt"][0], key_pos=per["key_pos"][0],
                  pos=per["pos"][0])
    for impl in ("xla", "pallas"):
        y_per, c_per = A.attend_decode_paged(params, cfg, spec, x,
                                             dict(per), impl)
        y_sh, c_sh = A.attend_decode_paged(params, cfg, spec, x,
                                           dict(shared), impl)
        np.testing.assert_array_equal(np.asarray(y_per), np.asarray(y_sh))
        np.testing.assert_array_equal(np.asarray(c_per["key_pos"][0]),
                                      np.asarray(c_sh["key_pos"]))
        assert c_sh["pos"].ndim == 0 and int(c_sh["pos"]) == 21


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_attend_decode_paged_write_mask_scratch_isolation(impl):
    """A write-masked row must scatter to the scratch block only: no live
    slot's pool blocks change, the masked row's key_pos/pos freeze, and the
    live rows' outputs equal an unmasked decode of the same rows."""
    from repro.models import attention as A
    cfg, spec, params, make_cache = _attn_fixture()
    b = 2
    x = jax.random.normal(K(27), (b, 1, cfg.d_model))
    cache = make_cache(b)
    cache["bt"] = jnp.array([[0, 1], [2, 3]], jnp.int32)
    cache["key_pos"] = cache["key_pos"].at[0, :20].set(jnp.arange(20))
    cache["key_pos"] = cache["key_pos"].at[1, :7].set(jnp.arange(7))
    cache["pos"] = jnp.array([20, 7], jnp.int32)
    wm = jnp.array([True, False])
    y, new = A.attend_decode_paged(params, cfg, spec, x, dict(cache), impl,
                                   write_mask=wm)
    scratch = cache["k_pool"].shape[0] - 1
    live = np.arange(scratch)                   # every non-scratch block
    row0_blocks = {0, 1}
    for k in ("k_pool", "v_pool"):
        for blk in live:
            if blk in row0_blocks:
                continue                        # row 0 wrote its own block
            np.testing.assert_array_equal(np.asarray(new[k][blk]),
                                          np.asarray(cache[k][blk]),
                                          err_msg=f"{k}[{blk}] corrupted")
    np.testing.assert_array_equal(np.asarray(new["key_pos"][1]),
                                  np.asarray(cache["key_pos"][1]))
    assert int(new["pos"][1]) == 7 and int(new["pos"][0]) == 21
    # row 0's output is independent of row 1 being masked
    y_solo, _ = A.attend_decode_paged(
        params, cfg, spec, x[:1],
        {**{k: v for k, v in cache.items() if "pool" in k},
         "bt": cache["bt"][:1], "key_pos": cache["key_pos"][:1],
         "pos": cache["pos"][:1]}, impl)
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(y_solo[0]),
                               rtol=1e-6, atol=1e-6)


def test_attend_decode_paged_unknown_impl_raises():
    from repro.models import attention as A
    cfg, spec, params, make_cache = _attn_fixture()
    x = jax.random.normal(K(28), (1, 1, cfg.d_model))
    cache = make_cache(1)
    with pytest.raises(ValueError, match="unknown decode impl"):
        A.attend_decode_paged(params, cfg, spec, x, cache, "cuda")
    with pytest.raises(ValueError, match="unknown decode impl"):
        A.attend_decode(params, cfg, spec, x, cache, "cuda")


# --------------------------------------------------------------------------- #
# RG-LRU scan
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("b,s,r", [(1, 16, 128), (2, 33, 200), (4, 7, 64),
                                   (1, 128, 384)])
def test_rglru_scan_matches_ref(b, s, r):
    ks = jax.random.split(K(5), 3)
    log_a = -jnp.abs(jax.random.normal(ks[0], (b, s, r)))
    bb = jax.random.normal(ks[1], (b, s, r))
    h0 = jax.random.normal(ks[2], (b, r))
    out = ops.rglru_scan(log_a, bb, h0, interpret=True)
    want = ref.rglru_scan_ref(log_a, bb, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_rglru_scan_zero_init_equals_none():
    ks = jax.random.split(K(6), 2)
    log_a = -jnp.abs(jax.random.normal(ks[0], (2, 9, 128)))
    bb = jax.random.normal(ks[1], (2, 9, 128))
    a = ops.rglru_scan(log_a, bb, None, interpret=True)
    b2 = ops.rglru_scan(log_a, bb, jnp.zeros((2, 128)), interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b2), rtol=0, atol=0)


if HAS_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 10_000), st.integers(1, 3), st.integers(1, 40),
           st.integers(1, 260))
    def test_rglru_scan_property(seed, b, s, r):
        ks = jax.random.split(K(seed), 3)
        log_a = -jnp.abs(jax.random.normal(ks[0], (b, s, r)))
        bb = jax.random.normal(ks[1], (b, s, r))
        h0 = jax.random.normal(ks[2], (b, r))
        out = ops.rglru_scan(log_a, bb, h0, interpret=True)
        want = ref.rglru_scan_ref(log_a, bb, h0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
else:       # keep the gap visible in test reports instead of not collecting
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_rglru_scan_property():
        pass


# --------------------------------------------------------------------------- #
# int8 matmul
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [(128, 512, 128), (70, 300, 130),
                                   (1, 1024, 256), (256, 64, 64)])
def test_int8_matmul_matches_ref(m, k, n, dtype):
    ks = jax.random.split(K(7), 2)
    x = jax.random.normal(ks[0], (m, k), dtype)
    w = jax.random.normal(ks[1], (k, n), jnp.float32)
    wq, sc = quantize_int8(w)
    out = ops.int8_matmul(x, wq, sc, interpret=True)
    want = ref.int8_matmul_ref(x, wq, sc)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_int8_quantization_error_bounded():
    w = jax.random.normal(K(8), (256, 128))
    wq, sc = quantize_int8(w)
    w_deq = wq.astype(jnp.float32) * sc
    # max per-element error is half a quantization step
    step = np.asarray(sc)[0]
    err = np.abs(np.asarray(w) - np.asarray(w_deq))
    assert (err <= step / 2 + 1e-6).all()


def test_int8_matmul_leading_dims():
    x = jax.random.normal(K(9), (2, 3, 64))
    w = jax.random.normal(K(10), (64, 32))
    wq, sc = quantize_int8(w)
    out = ops.int8_matmul(x, wq, sc, interpret=True)
    assert out.shape == (2, 3, 32)


# --------------------------------------------------------------------------- #
# model-level: pallas impl == xla impl
# --------------------------------------------------------------------------- #

def test_model_forward_pallas_matches_xla():
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("gemma2-2b").reduced(n_layers=2)
    params, _ = T.init_params(cfg, K(11))
    tokens = jax.random.randint(K(12), (2, 24), 0, cfg.vocab_size)
    ref_logits, _, _ = T.forward(cfg, params, tokens, mode="train", impl="xla")
    pal_logits, _, _ = T.forward(cfg, params, tokens, mode="train", impl="pallas")
    np.testing.assert_allclose(np.asarray(pal_logits), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)


def test_rglru_block_pallas_matches_xla():
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("recurrentgemma-2b").reduced(n_layers=3)
    params, _ = T.init_params(cfg, K(13))
    tokens = jax.random.randint(K(14), (2, 16), 0, cfg.vocab_size)
    a, _, _ = T.forward(cfg, params, tokens, mode="train", impl="xla")
    b = T.forward(cfg, params, tokens, mode="train", impl="pallas")[0]
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=2e-4, atol=2e-4)
