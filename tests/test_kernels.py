"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.int8_matmul import quantize_int8

K = jax.random.PRNGKey


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kh,d", [
    (1, 128, 4, 4, 64),       # MHA, exact block multiple
    (2, 200, 4, 2, 64),       # GQA, padded seq
    (1, 384, 8, 1, 128),      # MQA, d=128
    (1, 96, 2, 2, 32),        # seq < block
])
def test_flash_attention_matches_ref(b, s, h, kh, d, dtype):
    ks = jax.random.split(K(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kh, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kh, d), dtype)
    out = ops.flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention_ref(jnp.swapaxes(q, 1, 2),
                                   jnp.swapaxes(k, 1, 2),
                                   jnp.swapaxes(v, 1, 2))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(jnp.swapaxes(want, 1, 2), np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("window", [16, 64, 128])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_flash_attention_window_softcap(window, softcap):
    b, s, h, kh, d = 1, 256, 4, 2, 64
    ks = jax.random.split(K(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    out = ops.flash_attention(q, k, v, window=window, softcap=softcap,
                              interpret=True)
    want = ref.flash_attention_ref(jnp.swapaxes(q, 1, 2),
                                   jnp.swapaxes(k, 1, 2),
                                   jnp.swapaxes(v, 1, 2),
                                   window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.swapaxes(want, 1, 2)),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_block_shape_independence():
    """Result must not depend on the BlockSpec tiling."""
    b, s, h, kh, d = 1, 512, 2, 2, 64
    ks = jax.random.split(K(2), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    a = ops.flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    bq = ops.flash_attention(q, k, v, block_q=256, block_k=128, interpret=True)
    c = ops.flash_attention(q, k, v, block_q=128, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bq), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------- #
# decode attention
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kh,d,c,valid", [
    (2, 4, 2, 64, 512, 512),
    (1, 8, 1, 128, 700, 650),     # padded cache, partially filled
    (4, 2, 2, 32, 64, 10),
])
def test_decode_attention_matches_ref(b, h, kh, d, c, valid, dtype):
    ks = jax.random.split(K(3), 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, c, kh, d), dtype)
    vc = jax.random.normal(ks[2], (b, c, kh, d), dtype)
    key_pos = jnp.where(jnp.arange(c) < valid, jnp.arange(c), -1).astype(jnp.int32)
    pos = jnp.asarray(valid - 1, jnp.int32)
    out = ops.decode_attention(q, kc, vc, key_pos, pos, block_c=256,
                               interpret=True)
    mask = (key_pos >= 0) & (key_pos <= pos)
    want = ref.decode_attention_ref(q, kc, vc, mask[None])
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_ring_buffer_window():
    """Ring-buffer semantics: slots hold non-monotonic positions."""
    b, h, kh, d, c = 1, 2, 1, 32, 128
    ks = jax.random.split(K(4), 3)
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, c, kh, d))
    vc = jax.random.normal(ks[2], (b, c, kh, d))
    pos = jnp.asarray(200, jnp.int32)           # wrapped: slot = pos % 128
    key_pos = ((jnp.arange(c) + (201 // c) * c)
               - jnp.where(jnp.arange(c) > 200 % c, c, 0)).astype(jnp.int32)
    window = 50
    out = ops.decode_attention(q, kc, vc, key_pos, pos, window=window,
                               interpret=True)
    mask = (key_pos >= 0) & (key_pos <= pos) & (key_pos > pos - window)
    want = ref.decode_attention_ref(q, kc, vc, mask[None])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------------------- #
# RG-LRU scan
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("b,s,r", [(1, 16, 128), (2, 33, 200), (4, 7, 64),
                                   (1, 128, 384)])
def test_rglru_scan_matches_ref(b, s, r):
    ks = jax.random.split(K(5), 3)
    log_a = -jnp.abs(jax.random.normal(ks[0], (b, s, r)))
    bb = jax.random.normal(ks[1], (b, s, r))
    h0 = jax.random.normal(ks[2], (b, r))
    out = ops.rglru_scan(log_a, bb, h0, interpret=True)
    want = ref.rglru_scan_ref(log_a, bb, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_rglru_scan_zero_init_equals_none():
    ks = jax.random.split(K(6), 2)
    log_a = -jnp.abs(jax.random.normal(ks[0], (2, 9, 128)))
    bb = jax.random.normal(ks[1], (2, 9, 128))
    a = ops.rglru_scan(log_a, bb, None, interpret=True)
    b2 = ops.rglru_scan(log_a, bb, jnp.zeros((2, 128)), interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b2), rtol=0, atol=0)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 3), st.integers(1, 40),
       st.integers(1, 260))
def test_rglru_scan_property(seed, b, s, r):
    ks = jax.random.split(K(seed), 3)
    log_a = -jnp.abs(jax.random.normal(ks[0], (b, s, r)))
    bb = jax.random.normal(ks[1], (b, s, r))
    h0 = jax.random.normal(ks[2], (b, r))
    out = ops.rglru_scan(log_a, bb, h0, interpret=True)
    want = ref.rglru_scan_ref(log_a, bb, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------- #
# int8 matmul
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [(128, 512, 128), (70, 300, 130),
                                   (1, 1024, 256), (256, 64, 64)])
def test_int8_matmul_matches_ref(m, k, n, dtype):
    ks = jax.random.split(K(7), 2)
    x = jax.random.normal(ks[0], (m, k), dtype)
    w = jax.random.normal(ks[1], (k, n), jnp.float32)
    wq, sc = quantize_int8(w)
    out = ops.int8_matmul(x, wq, sc, interpret=True)
    want = ref.int8_matmul_ref(x, wq, sc)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_int8_quantization_error_bounded():
    w = jax.random.normal(K(8), (256, 128))
    wq, sc = quantize_int8(w)
    w_deq = wq.astype(jnp.float32) * sc
    # max per-element error is half a quantization step
    step = np.asarray(sc)[0]
    err = np.abs(np.asarray(w) - np.asarray(w_deq))
    assert (err <= step / 2 + 1e-6).all()


def test_int8_matmul_leading_dims():
    x = jax.random.normal(K(9), (2, 3, 64))
    w = jax.random.normal(K(10), (64, 32))
    wq, sc = quantize_int8(w)
    out = ops.int8_matmul(x, wq, sc, interpret=True)
    assert out.shape == (2, 3, 32)


# --------------------------------------------------------------------------- #
# model-level: pallas impl == xla impl
# --------------------------------------------------------------------------- #

def test_model_forward_pallas_matches_xla():
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("gemma2-2b").reduced(n_layers=2)
    params, _ = T.init_params(cfg, K(11))
    tokens = jax.random.randint(K(12), (2, 24), 0, cfg.vocab_size)
    ref_logits, _, _ = T.forward(cfg, params, tokens, mode="train", impl="xla")
    pal_logits, _, _ = T.forward(cfg, params, tokens, mode="train", impl="pallas")
    np.testing.assert_allclose(np.asarray(pal_logits), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)


def test_rglru_block_pallas_matches_xla():
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("recurrentgemma-2b").reduced(n_layers=3)
    params, _ = T.init_params(cfg, K(13))
    tokens = jax.random.randint(K(14), (2, 16), 0, cfg.vocab_size)
    a, _, _ = T.forward(cfg, params, tokens, mode="train", impl="xla")
    b = T.forward(cfg, params, tokens, mode="train", impl="pallas")[0]
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=2e-4, atol=2e-4)
