"""EdgeShard shard_map pipeline runtime vs single-device reference.

These tests need >1 XLA device, so they re-exec themselves in a subprocess
with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the flag must be set
before jax initializes, and the main test process must keep seeing 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(body: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


COMMON = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import transformer as T
from repro.core import pipeline as PL
cfg = get_config("qwen3-0.6b").reduced(n_layers=6)
params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
mesh = jax.make_mesh((2, 4), ("data", "model"))
"""


@pytest.mark.slow
def test_pipeline_forward_matches_reference_uneven_stages():
    run_subprocess(COMMON + """
spec = PL.PipelineSpec(4, (1, 2, 2, 1))
stage_params, mask = PL.stack_stage_params(cfg, params, spec)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
ref, _, _ = T.forward(cfg, params, tokens, mode="train")
with mesh:
    out = PL.pipeline_forward(cfg, stage_params, mask, tokens, spec, mesh,
                              n_microbatches=4)
np.testing.assert_allclose(np.asarray(out, np.float32),
                           np.asarray(ref, np.float32), rtol=3e-4, atol=3e-4)
""")


@pytest.mark.slow
def test_pipeline_forward_other_stage_layouts():
    run_subprocess(COMMON + """
for sizes in [(3, 1, 1, 1), (1, 1, 1, 3), (2, 2, 1, 1)]:
    spec = PL.PipelineSpec(4, sizes)
    stage_params, mask = PL.stack_stage_params(cfg, params, spec)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0, cfg.vocab_size)
    ref, _, _ = T.forward(cfg, params, tokens, mode="train")
    with mesh:
        out = PL.pipeline_forward(cfg, stage_params, mask, tokens, spec, mesh,
                                  n_microbatches=2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-4, atol=3e-4)
""")


@pytest.mark.slow
def test_pipeline_decode_matches_reference_with_diverse_streams():
    """Feed externally-chosen random tokens so each micro-batch builds a
    distinct KV history; sampled outputs must match per-mb references."""
    run_subprocess(COMMON + """
spec = PL.PipelineSpec(4, (2, 1, 2, 1))
stage_params, mask = PL.stack_stage_params(cfg, params, spec)
M, mb, max_len, gen = 4, 2, 32, 6
rng = np.random.default_rng(0)
feeds = rng.integers(0, cfg.vocab_size, size=(M, gen, mb)).astype(np.int32)

ref_tokens = []
for m in range(M):
    caches = T.init_caches(cfg, batch=mb, max_len=max_len, dtype=jnp.float32)
    seq = []
    for g in range(gen):
        logits, caches = T.decode_step(cfg, params, jnp.asarray(feeds[m, g]), caches)
        seq.append(np.asarray(jnp.argmax(logits, -1)))
    ref_tokens.append(np.stack(seq))
ref_tokens = np.stack(ref_tokens)

with mesh:
    state = PL.init_pipeline_decode_state(cfg, spec, M, mb, max_len,
                                          dtype=jnp.float32)
    rounds = {m: 0 for m in range(M)}
    got = {m: [] for m in range(M)}
    for t in range(M * gen + spec.n_stages + 4):
        f = t % M
        feed = jnp.asarray(feeds[f, min(rounds[f], gen - 1)])
        rounds[f] += 1
        state = PL.pipeline_decode_tick(cfg, stage_params, mask, state, feed,
                                        spec, mesh)
        dm = (t - (spec.n_stages - 1)) % M
        if t >= spec.n_stages - 1 and len(got[dm]) < gen:
            got[dm].append(np.argmax(np.asarray(state.logits_out[dm]),
                                     -1).astype(np.int32))
        if all(len(got[m]) >= gen for m in range(M)):
            break
pipe_tokens = np.stack([np.stack(got[m][:gen]) for m in range(M)])
assert len(np.unique(ref_tokens)) > 2, "degenerate reference"
np.testing.assert_array_equal(pipe_tokens, ref_tokens)
""")


@pytest.mark.slow
def test_moe_expert_parallel_matches_ragged():
    """EP all_to_all path == dropless ragged path (capacity generous)."""
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.models import transformer as T, moe as M
from repro.sharding.rules import use_mesh
cfg = get_config("granite-moe-1b-a400m").reduced(n_layers=2)
moe = cfg.pattern[0].moe
assert moe is not None and moe.num_experts % 4 == 0
params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
moe_params = params["stack"]["p0"]["ffn"]
moe_params = jax.tree.map(lambda x: x[0], moe_params)
x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
y_ragged, aux_r = M.moe_ragged(moe_params, moe, x)
mesh = jax.make_mesh((2, 4), ("data", "model"))
with use_mesh(mesh):
    y_ep, aux_e = M.moe_ep(moe_params, moe, x, capacity_factor=8.0)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ragged),
                           rtol=2e-4, atol=2e-4)
""")


@pytest.mark.slow
def test_full_model_pjit_sharded_matches_unsharded():
    """Whole-model forward under a (data, model) mesh with sharding
    constraints == unsharded forward (MoE uses the EP path)."""
    run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import transformer as T
from repro.sharding.rules import use_mesh, param_sharding_tree
for name in ["qwen3-0.6b", "granite-moe-1b-a400m", "gemma2-2b"]:
    cfg = get_config(name).reduced(n_layers=4)
    params, axes = T.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                cfg.vocab_size)
    ref, _, _ = T.forward(cfg, params, tokens, mode="train")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        shardings = param_sharding_tree(axes)
        params_s = jax.device_put(params, shardings)
        fn = jax.jit(lambda p, t: T.forward(cfg, p, t, mode="train")[0])
        out = fn(params_s, tokens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-4, atol=5e-4)
    print(name, "sharded OK")
""")


@pytest.mark.slow
def test_pipeline_decode_vocab_sharded_matches_plain():
    """§Perf-C2: stage-axis vocab-sharded embed/head tick == plain tick
    (embedding psum reconstruction + scatter/psum logits reassembly).

    The returned full logits are compared elementwise — a strictly
    stronger check than the argmax equality the pre-logits-ring version
    used, and free of that version's flakiness on near-tied logits."""
    run_subprocess(COMMON + """
spec = PL.PipelineSpec(4, (2, 1, 2, 1))
assert cfg.vocab_size % spec.n_stages == 0
stage_params, mask = PL.stack_stage_params(cfg, params, spec)
M, mb, max_len = 4, 2, 32
rng = np.random.default_rng(0)
with mesh:
    s_plain = PL.init_pipeline_decode_state(cfg, spec, M, mb, max_len,
                                            dtype=jnp.float32)
    s_vs = PL.init_pipeline_decode_state(cfg, spec, M, mb, max_len,
                                         dtype=jnp.float32)
    for t in range(12):
        feed = jnp.asarray(rng.integers(0, cfg.vocab_size, mb), jnp.int32)
        s_plain = PL.pipeline_decode_tick(cfg, stage_params, mask, s_plain,
                                          feed, spec, mesh)
        s_vs = PL.pipeline_decode_tick(cfg, stage_params, mask, s_vs,
                                       feed, spec, mesh, vocab_sharded=True)
    np.testing.assert_array_equal(np.asarray(s_plain.token_ready),
                                  np.asarray(s_vs.token_ready))
    np.testing.assert_allclose(np.asarray(s_plain.logits_out),
                               np.asarray(s_vs.logits_out),
                               rtol=2e-3, atol=2e-3)
    for a, b in zip(jax.tree.leaves(s_plain.caches),
                    jax.tree.leaves(s_vs.caches)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-4)
    assert len(np.unique(np.argmax(np.asarray(s_vs.logits_out), -1))) > 1
""")


def test_spec_from_plan_property():
    """Any DP plan (arbitrary contiguous stage sizes) maps to a valid
    PipelineSpec: all periods covered, n_stages respected."""
    import numpy as np
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.configs import get_config
    from repro.core.partition import Plan
    from repro.core.pipeline import spec_from_plan

    cfg = get_config("starcoder2-7b")           # 32 homogeneous layers

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(1, 20), min_size=1, max_size=8),
           st.integers(2, 16))
    def body(sizes, n_stages):
        # build a contiguous assignment over units [embed + 32 blocks + head]
        n_units = cfg.n_layers + 2
        sizes = np.asarray(sizes, float)
        bounds = np.cumsum(sizes / sizes.sum() * n_units).astype(int)
        bounds[-1] = n_units
        assignment = np.zeros(n_units, int)
        start = 0
        for dev, end in enumerate(bounds):
            assignment[start:end] = dev
            start = end
        plan = Plan(assignment, 1.0, "throughput")
        spec = spec_from_plan(cfg, plan, n_stages)
        assert spec.n_stages == n_stages
        assert spec.n_periods == cfg.n_full_periods
        assert all(p >= 0 for p in spec.periods_per_stage)

    body()
