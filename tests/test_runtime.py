"""Unified runtime: backend protocol, continuous batching, planner->backend.

Multi-device pipeline tests re-exec in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (same pattern as
test_pipeline_runtime.py); single-device tests run inline.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(body: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_tensor_pipeline_greedy_parity_under_batcher():
    """Acceptance: ContinuousBatcher over PipelineBackend (>= 2 stages,
    uneven periods-per-stage from a planner Plan) produces greedy outputs
    token-for-token identical to TensorBackend — including slot recycling
    (more requests than slots)."""
    run_subprocess("""
import jax, numpy as np
from repro.configs import get_config
from repro.core import pipeline as PL
from repro.core.devices import DeviceSpec, ClusterSpec, uniform_bandwidth, GIB
from repro.core.partition import solve_throughput
from repro.core.planner import build_problem
from repro.core.profile import Workload
from repro.models import transformer as T
from repro.runtime import PipelineBackend, TensorBackend
from repro.serving import ContinuousBatcher, Request, SamplingParams

cfg = get_config("qwen3-0.6b").reduced(n_layers=6)
params, _ = T.init_params(cfg, jax.random.PRNGKey(0))

# heterogeneous 3-device cluster so the throughput DP plans uneven stages
devs = [DeviceSpec("big", 64 * GIB, 40e12, 500e9),
        DeviceSpec("mid", 64 * GIB, 20e12, 250e9),
        DeviceSpec("small", 64 * GIB, 10e12, 125e9)]
cluster = ClusterSpec(devs, uniform_bandwidth(3, 1e9))
prob = build_problem(cfg, cluster, Workload(dtype_bytes=2))
plan = solve_throughput(prob)
spec = PL.spec_from_plan(cfg, plan, 3)
assert spec.n_stages >= 2
assert len(set(spec.periods_per_stage)) > 1, spec   # genuinely uneven

mesh = jax.make_mesh((1, 3), ("data", "model"))
rng = np.random.default_rng(0)
N, plen, gen = 7, 6, 5
prompts = rng.integers(0, cfg.vocab_size, (N, plen)).astype(np.int32)

def serve(backend):
    b = ContinuousBatcher(backend)
    for uid in range(N):
        b.submit(Request(prompts[uid], SamplingParams(max_tokens=gen),
                         uid=uid))
    done = b.run()
    assert sorted(done) == list(range(N))
    return np.stack([done[u].generated for u in range(N)])

pipe = serve(PipelineBackend(cfg, params, spec, mesh, n_slots=4, max_len=32))
tens = serve(TensorBackend(cfg, params, n_slots=4, max_len=32))
assert len(np.unique(tens)) > 2, "degenerate reference"
np.testing.assert_array_equal(pipe, tens)
""")


@pytest.mark.slow
def test_from_deployment_pipeline_matches_tensor():
    """planner Deployment -> running PipelineBackend in one call."""
    run_subprocess("""
import jax, numpy as np
from repro import runtime
from repro.configs import get_config
from repro.core.devices import tpu_pod_cluster
from repro.core.planner import plan_deployment
from repro.core.profile import Workload
from repro.models import transformer as T
from repro.serving import ContinuousBatcher, Request, SamplingParams

cfg = get_config("qwen3-0.6b").reduced(n_layers=4)
params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
cluster = tpu_pod_cluster(n_chips=2)
dep = plan_deployment(cfg, cluster, Workload(dtype_bytes=2),
                      objective="throughput")
backend = runtime.from_deployment(dep, cluster, cfg, kind="pipeline",
                                  params=params, max_len=32)
prompts = np.random.default_rng(1).integers(
    0, cfg.vocab_size, (3, 4)).astype(np.int32)

def serve(be):
    b = ContinuousBatcher(be)
    for uid in range(3):
        b.submit(Request(prompts[uid], SamplingParams(max_tokens=4), uid=uid))
    done = b.run()
    return np.stack([done[u].generated for u in range(3)])

pipe = serve(backend)
tens = serve(runtime.TensorBackend(cfg, params, n_slots=3, max_len=32))
np.testing.assert_array_equal(pipe, tens)
""")


# --------------------------------------------------------------------------- #
# single-device: scheduler behavior over TensorBackend / SimBackend
# --------------------------------------------------------------------------- #

def _tiny_tensor_backend(n_slots=2, max_len=64):
    import jax
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.runtime import TensorBackend
    cfg = get_config("qwen3-0.6b").reduced(n_layers=2)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, TensorBackend(cfg, params, n_slots=n_slots, max_len=max_len)


def test_scheduler_stats_staggered_arrival_completion():
    """Utilization accounting under staggered request arrival (at_step) and
    completion (different max_tokens): busy slot-steps land between the
    all-busy and single-slot bounds, and slots are recycled mid-flight."""
    from repro.serving import ContinuousBatcher, Request, SamplingParams
    cfg, backend = _tiny_tensor_backend(n_slots=2)
    rng = np.random.default_rng(0)
    b = ContinuousBatcher(backend)
    for uid, (n_tok, at) in enumerate(
            [(6, 0), (2, 0), (4, 3), (3, 8)]):
        b.submit(Request(rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                         SamplingParams(max_tokens=n_tok), uid=uid),
                 at_step=at)
    done = b.run()
    assert sorted(done) == [0, 1, 2, 3]
    for uid, (n_tok, _) in enumerate([(6, 0), (2, 0), (4, 3), (3, 8)]):
        assert len(done[uid].generated) == n_tok
    st = b.stats
    assert st.served == 4
    assert st.prefills >= 2                     # staggered admission waves
    assert st.slot_total_steps == 2 * st.decode_steps
    # staggered completion means some steps ran with an idle slot ...
    assert 0.0 < st.utilization < 1.0
    # ... but recycling keeps utilization above the no-recycling floor
    assert st.utilization > 0.5


def test_scheduler_per_request_sampling_state():
    """Mixed greedy + stochastic requests in one batch: greedy outputs match
    a pure-greedy run (per-request PRNG state is isolated)."""
    from repro.serving import ContinuousBatcher, Request, SamplingParams
    cfg, backend = _tiny_tensor_backend(n_slots=2)
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)

    b1 = ContinuousBatcher(backend, seed=7)
    b1.submit(Request(prompts[0], SamplingParams(max_tokens=5), uid=0))
    b1.submit(Request(prompts[1], SamplingParams(max_tokens=5,
                                                 temperature=1.0), uid=1))
    d1 = b1.run()

    _, backend2 = _tiny_tensor_backend(n_slots=2)
    b2 = ContinuousBatcher(backend2, seed=7)
    b2.submit(Request(prompts[0], SamplingParams(max_tokens=5), uid=0))
    d2 = b2.run()
    np.testing.assert_array_equal(d1[0].generated, d2[0].generated)


def test_sim_backend_nobubbles_beats_bubbles():
    """SimBackend under the batcher reproduces the Fig. 10 ordering."""
    from repro.core.simulator import StageCosts
    from repro.runtime import SimBackend
    from repro.serving import ContinuousBatcher, Request, SamplingParams
    costs = StageCosts(prefill=np.array([.02, .01, .03]),
                       decode=np.array([.002, .001, .003]),
                       comm_prefill=np.array([.004, .004]),
                       comm_decode=np.array([.0005, .0005]),
                       return_comm=.0005)
    thr = {}
    for schedule in ("bubbles", "nobubbles"):
        be = SimBackend(costs, n_slots=6, schedule=schedule)
        b = ContinuousBatcher(be)
        for uid in range(6):
            b.submit(Request(np.zeros(4, np.int32),
                             SamplingParams(max_tokens=16), uid=uid))
        done = b.run()
        assert all(len(r.generated) == 16 for r in done.values())
        thr[schedule] = be.sim_result().throughput
    assert thr["nobubbles"] > thr["bubbles"] * 1.01


def test_backend_info_metadata():
    from repro.runtime import SimBackend
    from repro.core.simulator import StageCosts
    cfg, backend = _tiny_tensor_backend(n_slots=3, max_len=32)
    info = backend.info
    assert info.n_slots == 3 and info.max_len == 32
    assert info.cache_bytes_per_slot > 0
    assert info.cache_bytes == 3 * info.cache_bytes_per_slot
    assert info.param_bytes > 0
    assert not info.samples_in_backend
    sim = SimBackend(StageCosts(np.array([.1]), np.array([.01]),
                                np.zeros(0), np.zeros(0), 0.0), n_slots=2)
    assert sim.info.samples_in_backend
