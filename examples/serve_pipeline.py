"""End-to-end driver: serve variable-length requests through the ``LLM``
facade on the EdgeShard shard_map pipeline (no-bubbles decode over 8 XLA
devices).

This is the paper's deployment mode on the TPU-native runtime:
1. ``LLM.from_plan`` plans an (uneven) stage partition with the throughput
   DP and materializes it as a running ``PipelineBackend`` (params restacked
   into per-stage slabs) behind one serving facade,
2. ``generate()`` streams requests of *different prompt lengths* through the
   no-bubbles tick protocol — more requests than micro-batch slots, so slots
   are recycled mid-flight, and admission buckets prompts by length (no
   caller-side padding),
3. cross-check every generated token against the TensorBackend (single
   engine) serving the identical requests,
4. demo the streaming interface on the tensor engine.

Must run in its own process (needs 8 host devices):
    PYTHONPATH=src python examples/serve_pipeline.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro import runtime
from repro.configs import get_config
from repro.core.devices import tpu_pod_cluster
from repro.core.profile import Workload
from repro.models import transformer as T
from repro.serving import LLM, SamplingParams


def main():
    cfg = get_config("qwen3-0.6b").reduced(n_layers=8, max_d_model=256)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    n_stages = 4

    # 1. plan (paper's throughput DP over a 4-chip homogeneous "cluster"
    #    profile) -> running pipeline backend -> serving facade, one call
    llm = LLM.from_plan(cfg, tpu_pod_cluster(n_chips=n_stages),
                        Workload(dtype_bytes=2), objective="throughput",
                        kind="pipeline", params=params, max_len=64)
    print(f"stage layout (periods per stage): "
          f"{llm.backend.spec.periods_per_stage}")

    # 2. continuous batching: 8 variable-length requests over 4 micro-batch
    #    slots (admission buckets by length; nobody pads)
    n_req, gen = 8, 8
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in rng.integers(3, 7, n_req)]
    sp = SamplingParams(max_tokens=gen)
    t0 = time.time()
    outs = llm.generate(prompts, sp)
    dt = time.time() - t0
    total = sum(o.n_generated for o in outs)
    print(f"pipeline: {total} tokens for prompt lengths "
          f"{[o.n_prompt for o in outs]} in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on CPU-interpreted SPMD) — {llm.stats}")

    # 3. verify against the tensor backend serving the same requests
    ref_llm = LLM.from_backend(
        runtime.TensorBackend(cfg, params, n_slots=4, max_len=64))
    refs = ref_llm.generate(prompts, sp)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o.tokens, r.tokens)
    print("all pipeline tokens match the tensor backend — OK")

    # 4. streaming: tokens surface the step they decode, interleaved across
    #    requests
    stream_llm = LLM.from_backend(
        runtime.TensorBackend(cfg, params, n_slots=2, max_len=64))
    events = list(stream_llm.stream(prompts[:2], SamplingParams(max_tokens=4)))
    for ev in events:
        print(f"  step {ev.step} req {ev.uid} tok[{ev.index}]={ev.token}"
              + (f" <{ev.finish_reason}>" if ev.finished else ""))
    assert sum(ev.finished for ev in events) == 2


if __name__ == "__main__":
    main()
