"""End-to-end driver: serve a small model with batched requests through the
EdgeShard shard_map pipeline (no-bubbles decode over 8 XLA devices).

This is the paper's deployment mode on the TPU-native runtime:
1. plan an (uneven) stage partition with the throughput DP,
2. restack params into per-stage slabs on a (data, model) mesh,
3. stream micro-batched requests through the no-bubbles tick protocol,
4. cross-check every generated token against single-device decode.

Must run in its own process (needs 8 host devices):
    PYTHONPATH=src python examples/serve_pipeline.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import pipeline as PL
from repro.core.devices import tpu_pod_cluster
from repro.core.partition import solve_throughput
from repro.core.planner import build_problem
from repro.core.profile import Workload
from repro.models import transformer as T


def main():
    cfg = get_config("qwen3-0.6b").reduced(n_layers=8, max_d_model=256)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    n_stages = 4

    # 1. plan the partition with the paper's throughput DP over a 4-chip
    #    homogeneous "cluster" (uneven only if the cost model says so; force
    #    an uneven layout here to exercise the mechanism)
    prob = build_problem(cfg, tpu_pod_cluster(n_chips=n_stages),
                         Workload(dtype_bytes=2))
    plan = solve_throughput(prob)
    spec = PL.spec_from_plan(cfg, plan, n_stages)
    print(f"stage layout (periods per stage): {spec.periods_per_stage}")

    # 2. restack params into stage slabs
    stage_params, mask = PL.stack_stage_params(cfg, params, spec)

    # 3. no-bubbles decode: M micro-batches in flight
    M, mb, max_len, gen = 4, 2, 64, 8
    rng = np.random.default_rng(0)
    first = rng.integers(0, cfg.vocab_size, size=(M, mb)).astype(np.int32)
    tick_fn = jax.jit(lambda st, feed: PL.pipeline_decode_tick(
        cfg, stage_params, mask, st, feed, spec, mesh))
    with mesh:
        state = PL.init_pipeline_decode_state(cfg, spec, M, mb, max_len,
                                              dtype=jnp.float32)
        cur = {m: first[m] for m in range(M)}
        got = {m: [] for m in range(M)}
        t0 = time.time()
        t = 0
        while not all(len(got[m]) >= gen for m in range(M)):
            f = t % M
            state = tick_fn(state, jnp.asarray(cur[f]))
            dm = (t - (spec.n_stages - 1)) % M
            if t >= spec.n_stages - 1 and len(got[dm]) < gen:
                tok = np.asarray(state.tokens_out[dm])
                got[dm].append(tok)
                cur[dm] = tok
            t += 1
        dt = time.time() - t0
    total = M * mb * gen
    print(f"pipeline: {total} tokens in {t} ticks / {dt:.2f}s "
          f"({total / dt:.1f} tok/s on CPU-interpreted SPMD)")

    # 4. verify against single-device decode
    for m in range(M):
        caches = T.init_caches(cfg, mb, max_len, jnp.float32)
        tok = jnp.asarray(first[m])
        for g in range(gen):
            logits, caches = T.decode_step(cfg, params, tok, caches)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            np.testing.assert_array_equal(np.asarray(tok), got[m][g])
    print("all pipeline tokens match single-device decode — OK")


if __name__ == "__main__":
    main()
