"""End-to-end driver: serve batched requests through the unified runtime on
the EdgeShard shard_map pipeline (no-bubbles decode over 8 XLA devices).

This is the paper's deployment mode on the TPU-native runtime:
1. plan an (uneven) stage partition with the throughput DP,
2. ``runtime.from_deployment`` turns the plan into a running
   ``PipelineBackend`` (params restacked into per-stage slabs),
3. ``ContinuousBatcher`` streams requests through the no-bubbles tick
   protocol — more requests than micro-batch slots, so slots are recycled
   mid-flight,
4. cross-check every generated token against the TensorBackend (single
   engine) serving the identical requests.

Must run in its own process (needs 8 host devices):
    PYTHONPATH=src python examples/serve_pipeline.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro import runtime
from repro.configs import get_config
from repro.core.devices import tpu_pod_cluster
from repro.core.planner import plan_deployment
from repro.core.profile import Workload
from repro.models import transformer as T
from repro.serving import ContinuousBatcher, Request, SamplingParams


def serve(backend, prompts, gen, seed=0):
    batcher = ContinuousBatcher(backend, prompt_len=prompts.shape[1],
                                seed=seed)
    for uid in range(len(prompts)):
        batcher.submit(Request(uid, prompts[uid],
                               SamplingParams(max_tokens=gen)))
    t0 = time.time()
    done = batcher.run()
    dt = time.time() - t0
    toks = np.stack([done[u].generated for u in range(len(prompts))])
    return toks, dt, batcher.stats


def main():
    cfg = get_config("qwen3-0.6b").reduced(n_layers=8, max_d_model=256)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    n_stages = 4

    # 1. plan the partition with the paper's throughput DP over a 4-chip
    #    homogeneous "cluster" profile
    cluster = tpu_pod_cluster(n_chips=n_stages)
    dep = plan_deployment(cfg, cluster, Workload(dtype_bytes=2),
                          objective="throughput")

    # 2. plan -> running backend in one call
    mesh = jax.make_mesh((1, n_stages), ("data", "model"))
    backend = runtime.from_deployment(dep, cluster, cfg, kind="pipeline",
                                      params=params, mesh=mesh, max_len=64)
    print(f"stage layout (periods per stage): "
          f"{backend.spec.periods_per_stage}")

    # 3. continuous batching: 8 requests over 4 micro-batch slots
    n_req, plen, gen = 8, 4, 8
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (n_req, plen)).astype(np.int32)
    toks, dt, stats = serve(backend, prompts, gen)
    total = toks.size
    print(f"pipeline: {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s on CPU-interpreted SPMD) — {stats}")

    # 4. verify against the tensor backend serving the same requests
    ref_backend = runtime.TensorBackend(cfg, params, n_slots=4, max_len=64)
    ref, _, _ = serve(ref_backend, prompts, gen)
    np.testing.assert_array_equal(toks, ref)
    print("all pipeline tokens match the tensor backend — OK")


if __name__ == "__main__":
    main()
