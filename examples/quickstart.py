"""Quickstart: plan an EdgeShard deployment and inspect it.

Runs the paper's pipeline end-to-end on the decision layer: profile
Llama2-7B, solve the joint device-selection + partition DPs on the paper's
15-device testbed, and simulate latency/throughput for every method of
Table IV.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import PAPER_MODELS
from repro.core import Workload, baseline_suite, paper_testbed
from repro.core.devices import MBPS


def main():
    cfg = PAPER_MODELS["llama2-7b"]
    cluster = paper_testbed(cloud_bw=1 * MBPS)      # 12x AGX, 2x NX, 1x RTX3090
    workload = Workload(prompt_len=32, gen_tokens=96, batch=1, dtype_bytes=4)

    print(f"model: {cfg.name} ({cfg.param_count() / 1e9:.2f}B params)")
    print(f"cluster: {len(cluster.devices)} devices, "
          f"source={cluster.devices[0].name}, cloud link 1 Mbps\n")

    suite = baseline_suite(cfg, cluster, workload, n_microbatches=8)
    print(f"{'method':24s} {'latency':>12s} {'throughput':>12s} {'devices':>8s}")
    for name, d in suite.items():
        if d.oom:
            print(f"{name:24s} {'OOM':>12s} {'OOM':>12s} {'-':>8s}")
        else:
            print(f"{name:24s} {d.latency_ms_per_token:10.2f}ms "
                  f"{d.throughput_tok_s:8.2f}t/s {len(d.plan.devices_used):8d}")

    es = suite["edgeshard"]
    print("\nEdgeShard plan (unit ranges -> device):")
    for st in es.plan.stages:
        dev = cluster.devices[st.device]
        print(f"  units {st.start:3d}..{st.end:3d} -> device {st.device:2d} "
              f"({dev.name})")


if __name__ == "__main__":
    main()
