"""Quickstart: plan an EdgeShard deployment, inspect it, and serve it.

Runs the paper's pipeline end-to-end on the decision layer: profile
Llama2-7B, solve the joint device-selection + partition DPs on the paper's
15-device testbed, simulate latency/throughput for every method of
Table IV — then serve requests over the planned deployment through the
``LLM`` facade (here on the simulated backend, so it runs instantly with no
model weights; swap ``kind="pipeline", params=...`` for the real thing).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import PAPER_MODELS
from repro.core import Workload, baseline_suite, paper_testbed
from repro.core.devices import MBPS
from repro.serving import LLM, SamplingParams


def main():
    cfg = PAPER_MODELS["llama2-7b"]
    cluster = paper_testbed(cloud_bw=1 * MBPS)      # 12x AGX, 2x NX, 1x RTX3090
    workload = Workload(prompt_len=32, gen_tokens=96, batch=1, dtype_bytes=4)

    print(f"model: {cfg.name} ({cfg.param_count() / 1e9:.2f}B params)")
    print(f"cluster: {len(cluster.devices)} devices, "
          f"source={cluster.devices[0].name}, cloud link 1 Mbps\n")

    suite = baseline_suite(cfg, cluster, workload, n_microbatches=8)
    print(f"{'method':24s} {'latency':>12s} {'throughput':>12s} {'devices':>8s}")
    for name, d in suite.items():
        if d.oom:
            print(f"{name:24s} {'OOM':>12s} {'OOM':>12s} {'-':>8s}")
        else:
            print(f"{name:24s} {d.latency_ms_per_token:10.2f}ms "
                  f"{d.throughput_tok_s:8.2f}t/s {len(d.plan.devices_used):8d}")

    es = suite["edgeshard"]
    print("\nEdgeShard plan (unit ranges -> device):")
    for st in es.plan.stages:
        dev = cluster.devices[st.device]
        print(f"  units {st.start:3d}..{st.end:3d} -> device {st.device:2d} "
              f"({dev.name})")

    # --- serve the planned deployment (plan -> backend -> requests in one
    #     call; variable-length prompts, no padding by the caller) ---------
    llm = LLM.from_plan(cfg, cluster, workload, objective="throughput",
                        kind="sim")
    outs = llm.generate([list(range(24)), list(range(9)), list(range(40))],
                        SamplingParams(max_tokens=workload.gen_tokens))
    print("\nserved over the planned deployment (simulated):")
    for o in outs:
        print(f"  req {o.uid}: {o.n_prompt:2d} prompt -> {o.n_generated} "
              f"tokens ({o.finish_reason})")
    sim = llm.backend.sim_result()
    print(f"  simulated throughput {sim.throughput:.1f} tok/s — {llm.stats}")


if __name__ == "__main__":
    main()
