"""Train a small qwen3-family model on the synthetic LM stream.

Defaults are CPU-budget friendly (a ~3M-param model, 200 steps); pass
--d-model 768 --layers 12 --steps 300 for a ~100M-param run on real
hardware.

    PYTHONPATH=src python examples/train_tiny.py [--steps 200]
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.training import AdamWConfig, DataConfig, TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    base = get_config("qwen3-0.6b").reduced(
        n_layers=args.layers, max_d_model=args.d_model, vocab=512)
    cfg = dataclasses.replace(base, n_layers=args.layers)
    print(f"model: {cfg.param_count() / 1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model})")

    tcfg = TrainConfig(
        steps=args.steps, log_every=max(args.steps // 20, 1),
        ckpt_dir=args.ckpt_dir,
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=args.steps // 10,
                              total_steps=args.steps))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      batch=args.batch)
    metrics = train(cfg, tcfg, dcfg)
    print(f"\nfirst loss {metrics['first_loss']:.3f} -> "
          f"final loss {metrics['final_loss']:.3f} "
          f"(mean last-10: {metrics['mean_last10']:.3f})")
    assert metrics["final_loss"] < metrics["first_loss"]


if __name__ == "__main__":
    main()
