"""Partition planner CLI — the paper's scheduling-optimization stage.

    PYTHONPATH=src python examples/partition_plan.py \
        --arch llama2-13b --objective throughput --cloud-bw 10

Shows how the DP's device selection and layer partition change with the
objective (Algo. 1 vs Algo. 2), bandwidth, and quantization (int8 halves
every Req_i, changing feasibility — the paper's §II motivation).
"""
import argparse

from repro.configs import CONFIGS, get_config
from repro.core import Workload, build_problem, paper_testbed
from repro.core.devices import MBPS
from repro.core.partition import solve_latency_best, solve_throughput
from repro.core.planner import _evaluate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b", choices=sorted(CONFIGS))
    ap.add_argument("--objective", default="latency",
                    choices=["latency", "throughput"])
    ap.add_argument("--cloud-bw", type=float, default=1.0, help="Mbps")
    ap.add_argument("--edge-bw", type=float, default=50.0, help="Mbps")
    ap.add_argument("--int8", action="store_true",
                    help="weight-only int8 (halves memory requirements)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    cluster = paper_testbed(cloud_bw=args.cloud_bw * MBPS,
                            edge_bw=args.edge_bw * MBPS)
    dtype_bytes = 1 if args.int8 else 4
    workload = Workload(prompt_len=32, gen_tokens=96, batch=1,
                        dtype_bytes=dtype_bytes)
    prob = build_problem(cfg, cluster, workload)
    solver = solve_latency_best if args.objective == "latency" \
        else solve_throughput
    plan = solver(prob)
    if plan.objective == float("inf"):
        print("INFEASIBLE: model does not fit the cluster memory")
        return
    print(f"{args.arch} | objective={args.objective} | "
          f"cloud {args.cloud_bw} Mbps | "
          f"{'int8' if args.int8 else 'fp32'}")
    print(f"DP objective: {plan.objective * 1e3:.3f} ms")
    for st in plan.stages:
        dev = cluster.devices[st.device]
        n_units = st.end - st.start + 1
        print(f"  {n_units:3d} units [{st.start:3d}..{st.end:3d}] -> "
              f"dev{st.device:2d} {dev.name}")
    dep = _evaluate(cfg, cluster, workload, plan, "plan", n_microbatches=8)
    print(f"simulated: {dep.latency_ms_per_token:.2f} ms/token, "
          f"{dep.throughput_tok_s:.2f} tok/s @ batch {dep.batch}")


if __name__ == "__main__":
    main()
